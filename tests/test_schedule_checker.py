"""Schedule-checker tests (DESIGN.md §19).

Covers the five invariant families of ``repro.analysis.schedule`` —
coverage, exclusivity/race-freedom, bounds, padding soundness,
determinism — three ways:

- **property tests** (random sparsity patterns × all six dataflows ×
  mixed × 1/2/8 shards): the checker accepts every planner-emitted
  schedule with zero diagnostics;
- **mutation tests**: each family rejects a schedule mutated to violate
  exactly that invariant, surfacing *its* stable diagnostic code;
- **cache regression**: ``verify_cache`` catches a re-targeted plan
  re-admitted into the LRU with a stale/foreign schedule (fails against
  the PR-9 verifier, which never looked at ``plan.aux``).
"""
import dataclasses
import functools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import DistPartition, MemoryBudget, PlanCache, flexagon_plan
from repro.analysis import errors_of, verify_cache, verify_plan
from repro.core import dataflows as df
from repro.core import random_sparse_dense

BS = (16, 16, 16)


def _operands(seed=0, shape=(64, 48, 80), da=0.35, db=0.45):
    rng = np.random.default_rng(seed)
    m, k, n = shape
    a = random_sparse_dense(rng, (m, k), density=da, block_shape=BS[:2])
    b = random_sparse_dense(rng, (k, n), density=db, block_shape=BS[1:])
    return a, b


def _codes(diags):
    return {d.code for d in diags}


@functools.lru_cache(maxsize=None)
def _base_plan(dataflow="op_m"):
    """One cached pallas plan for the mutation tests (never mutated in
    place — every mutation goes through ``dataclasses.replace`` copies)."""
    # dense enough that destination runs merge several (A, B) pairs —
    # the determinism mutation needs a multi-entry run to reorder
    a, b = _operands(seed=3, da=0.8, db=0.8)
    return flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                         backend="pallas", verify=False)


def _with_schedule(plan, sched):
    return dataclasses.replace(plan, aux={**plan.aux,
                                          "stream_schedule": sched})


def _mutate(sched, **arrays):
    """Replace schedule fields with modified *copies* of the originals."""
    return dataclasses.replace(
        sched, **{k: np.asarray(v) for k, v in arrays.items()})


def test_verify_cache_catches_retargeted_readmission():
    """Pre-fix regression (the PR-9 verifier returned ``[]`` here).

    A serving loop re-targets a cached plan with ``with_backend`` and
    re-admits it into the LRU.  If the re-admitted plan carries a stale
    or foreign aux schedule (here: another pattern's schedule — exactly
    what a buggy re-admission that skips ``prepare`` produces), only the
    original insertion was ever verified: ``verify_cache`` checked key
    agreement and plan *structure* but never the aux schedule, so the
    corrupt entry was served silently.
    """
    cache = PlanCache()
    a, b = _operands(seed=0)
    plan = cache.get(a, b, dataflow="op_m", block_shape=BS,
                     backend="pallas", verify=False)
    assert "stream_schedule" in plan.aux
    key = next(iter(cache._plans))

    a2, b2 = _operands(seed=9, shape=(48, 64, 48), da=0.2, db=0.3)
    other = flexagon_plan(a2, b2, dataflow="op_m", block_shape=BS,
                          backend="pallas", verify=False)
    stale = dataclasses.replace(plan, aux=dict(plan.aux))
    stale.aux["stream_schedule"] = other.aux["stream_schedule"]
    cache._plans[key] = stale          # the LRU re-admission

    codes = _codes(verify_cache(cache))
    assert codes & {"schedule-coverage", "schedule-determinism",
                    "schedule-bounds"}, codes


# ---------------------------------------------------------------------------
# property tests: the checker accepts every planner-emitted schedule
# ---------------------------------------------------------------------------


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000),
       m=st.sampled_from((32, 48, 64)),
       k=st.sampled_from((32, 48, 64)),
       n=st.sampled_from((32, 48, 64)),
       da=st.floats(0.1, 0.6),
       db=st.floats(0.1, 0.6))
def test_checker_accepts_all_dataflows(seed, m, k, n, da, db):
    """Random sparsity x {six dataflows, mixed}: zero diagnostics."""
    a, b = _operands(seed=seed, shape=(m, k, n), da=da, db=db)
    budget = MemoryBudget(l1_bytes=1024, l2_bytes=2048)
    for dataflow in list(df.DATAFLOWS) + ["mixed"]:
        plan = flexagon_plan(
            a, b, dataflow=dataflow, block_shape=BS, backend="pallas",
            verify=False,
            memory_budget=budget if dataflow == "mixed" else None)
        diags = verify_plan(plan)
        assert not errors_of(diags), (dataflow, [str(d) for d in diags])


@settings(max_examples=4)
@given(seed=st.integers(0, 10_000), da=st.floats(0.15, 0.5),
       db=st.floats(0.15, 0.5))
def test_checker_accepts_sharded_stacks(seed, da, db):
    """Random sparsity x {1, 2, 8 shards}: zero errors, stacks uniform."""
    a, b = _operands(seed=seed, shape=(128, 48, 64), da=da, db=db)
    for shards in (1, 2, 8):
        plan = flexagon_plan(
            a, b, dataflow="op_m", block_shape=BS, backend="pallas",
            verify=False,
            partition=DistPartition(shards=shards) if shards > 1 else None)
        diags = verify_plan(plan)
        assert not errors_of(diags), (shards, [str(d) for d in diags])


# ---------------------------------------------------------------------------
# mutation tests: each invariant family rejects its violated schedule
# ---------------------------------------------------------------------------


def test_mutation_structure_boundary_flags():
    """A cleared run-opening is_first breaks the accumulator discipline."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    assert s.n_real_work > 0
    flags = np.asarray(s.is_first).copy()
    flags[0] = 0
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(s,
                                                            is_first=flags))))
    assert "schedule-structure" in codes, codes


def test_mutation_bounds_operand_slot():
    """An out-of-range gather slot would DMA past the stored block stack."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    a_stored = plan.a_layout.rows.shape[0]
    slots = np.asarray(s.a_slot).copy()
    slots[0] = a_stored + 5
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(s,
                                                            a_slot=slots))))
    assert "schedule-bounds" in codes, codes


def test_mutation_bounds_run_destination():
    """A real run scattering outside the output grid is out of bounds."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    m, _, _ = plan.shapes
    rows_g = -(-m // BS[0])
    ci = np.asarray(s.run_ci).copy()
    ci[0] = rows_g + 3
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(s, run_ci=ci))))
    assert "schedule-bounds" in codes, codes


def test_mutation_race_duplicate_destination():
    """Two real runs claiming one C block: last writer wins, data lost."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    assert s.n_real_runs >= 2
    ci = np.asarray(s.run_ci).copy()
    cj = np.asarray(s.run_cj).copy()
    ci[1], cj[1] = ci[0], cj[0]
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(s, run_ci=ci,
                                                            run_cj=cj))))
    assert "schedule-race" in codes, codes


def test_mutation_pad_run_inside_grid():
    """A pad run retargeted inside the grid would overwrite real output.

    Also proves the positive direction first: a canonically *padded*
    schedule (what uniform_aux emits for stacked families) passes the
    whole checker, including the determinism re-derivation.
    """
    from repro.kernels.stream import pad_schedule

    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    m, _, _ = plan.shapes
    rows_g = -(-m // BS[0])
    oob = s.oob_row if s.oob_row >= 0 else rows_g
    padded = pad_schedule(s, s.n_work + 3, int(s.n_runs) + 1, oob)
    assert not errors_of(verify_plan(_with_schedule(plan, padded)))

    ci = np.asarray(padded.run_ci).copy()
    ci[-1] = 0                       # pad run now aliases a real output row
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(padded,
                                                            run_ci=ci))))
    assert "schedule-pad" in codes, codes


def test_mutation_coverage_retargeted_pair():
    """Rewriting one gathered slot drops a pair and invents another."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    a_stored = plan.a_layout.rows.shape[0]
    assert a_stored >= 2
    slots = np.asarray(s.a_slot).copy()
    slots[0] = (slots[0] + 1) % a_stored
    codes = _codes(verify_plan(_with_schedule(plan, _mutate(s,
                                                            a_slot=slots))))
    assert "schedule-coverage" in codes, codes


def test_mutation_determinism_reordered_merge():
    """A multiset-preserving reorder inside one run changes fp32
    accumulation order — everything else passes, determinism catches it."""
    plan = _base_plan()
    s = plan.aux["stream_schedule"]
    rid = np.asarray(s.run_id)
    a_slot = np.asarray(s.a_slot).copy()
    b_slot = np.asarray(s.b_slot).copy()
    idx = next((i for i in range(1, s.n_real_work)
                if rid[i] == rid[i - 1]
                and (a_slot[i] != a_slot[i - 1]
                     or b_slot[i] != b_slot[i - 1])), None)
    assert idx is not None, "expected a multi-entry run in the base plan"
    a_slot[idx - 1], a_slot[idx] = a_slot[idx], a_slot[idx - 1]
    b_slot[idx - 1], b_slot[idx] = b_slot[idx], b_slot[idx - 1]
    diags = verify_plan(_with_schedule(plan, _mutate(s, a_slot=a_slot,
                                                     b_slot=b_slot)))
    codes = _codes(diags)
    assert codes == {"schedule-determinism"}, [str(d) for d in diags]


def test_missing_schedule_on_pallas_plan():
    """A pallas plan whose aux lost its schedule is rejected outright."""
    plan = _base_plan()
    stripped = dataclasses.replace(
        plan, aux={k: v for k, v in plan.aux.items()
                   if k != "stream_schedule"})
    codes = _codes(verify_plan(stripped))
    assert "schedule-missing" in codes, codes


def test_stack_uniformity_on_sharded_plan():
    """A shard whose schedule extents drift breaks the shard_map stack."""
    from repro.kernels.stream import pad_schedule

    a, b = _operands(seed=5, shape=(128, 48, 64))
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         backend="pallas", verify=False,
                         partition=DistPartition(shards=2))
    assert plan.shard_ok and len(plan.plans) == 2
    member = plan.plans[1]
    s = member.aux["stream_schedule"]
    m_mem, _, _ = member.shapes
    rows_g = -(-m_mem // BS[0])
    oob = s.oob_row if s.oob_row >= 0 else rows_g
    grown = pad_schedule(s, s.n_work + 3, int(s.n_runs) + 1, oob)
    bad = dataclasses.replace(
        plan, plans=(plan.plans[0], _with_schedule(member, grown)))
    codes = _codes(verify_plan(bad))
    assert "schedule-stack" in codes, codes


# ---------------------------------------------------------------------------
# lint rule, index-map audit, unified CLI
# ---------------------------------------------------------------------------


def test_lint_schedule_call_rule(tmp_path):
    """Raw StreamSchedule/pallas_call outside kernels/ fails lint; the
    same construct inside kernels/ (and outside repro/) is allowed."""
    from repro.analysis import lint_paths

    pkg = tmp_path / "repro"
    (pkg / "kernels").mkdir(parents=True)
    bad = pkg / "helper.py"
    bad.write_text("from repro.kernels.stream import StreamSchedule\n"
                   "s = StreamSchedule(a, b, c, d, e, f, g, h, 4)\n")
    ok_kernel = pkg / "kernels" / "fused.py"
    ok_kernel.write_text("import jax.experimental.pallas as pl\n"
                         "out = pl.pallas_call(kernel, grid=(4,))\n")

    codes = {d.code for d in lint_paths([str(bad)])}
    assert "schedule-call" in codes, codes
    assert "schedule-call" not in {d.code
                                   for d in lint_paths([str(ok_kernel)])}


def test_index_map_report_clean_and_empty():
    from repro.analysis import index_map_report

    for kind in ("dest", "panel"):
        report = index_map_report(kind, 64, 16)
        assert report.clean, [str(d) for d in report.diagnostics]
        assert report.aval_hashes
    empty = index_map_report("dest", 0, 0)
    assert empty.clean and not empty.aval_hashes


def test_unified_cli_usage_and_lint():
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    env_src = str(root / "src")

    def run(*argv):
        import os
        env = dict(os.environ, PYTHONPATH=env_src)
        return subprocess.run([sys.executable, "-m", "repro.analysis",
                               *argv], cwd=root, env=env,
                              capture_output=True, text=True)

    usage = run()
    assert usage.returncode == 2
    assert "subcommands" in usage.stdout + usage.stderr

    lint = run("lint", "src/repro/analysis/schedule.py")
    assert lint.returncode == 0, lint.stdout + lint.stderr

    unknown = run("frobnicate")
    assert unknown.returncode == 2
