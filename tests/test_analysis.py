"""repro.analysis — plan verifier, jaxpr purity/retrace report, AST lint.

Mutation style: build a legitimate plan through ``flexagon_plan``, corrupt
exactly one invariant with ``dataclasses.replace``, and assert the verifier
reports the *expected diagnostic code* (not just "some error").  Clean
plans across every dataflow family must produce zero diagnostics — the
whole suite already runs with ``REPRO_VERIFY=1`` (tests/conftest.py), so a
verifier false-positive would fail far more than this file.
"""
import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import MemoryBudget, PlanCache, flexagon_plan
from repro.analysis import (ERROR, PlanDiagnostic, PlanVerificationError,
                            RetraceDetector, errors_of, lint_paths,
                            trace_report, verify_cache, verify_plan)
from repro.core import random_sparse_dense
from repro.core.dataflows import DATAFLOWS

BS = (16, 16, 16)
TILING = MemoryBudget(l1_bytes=4096, l2_bytes=16384)


def _operands(m=64, k=64, n=64, da=0.4, db=0.4, seed=0):
    rng = np.random.default_rng(seed)
    a = random_sparse_dense(rng, (m, k), density=da, block_shape=BS[:2])
    b = random_sparse_dense(rng, (k, n), density=db, block_shape=BS[1:])
    return a, b


def _codes(diags):
    return [d.code for d in diags]


# ---------------------------------------------------------------------------
# clean plans: zero diagnostics across every family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_clean_untiled_plan_verifies(dataflow):
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS)
    assert verify_plan(plan) == []


@pytest.mark.parametrize("dataflow", DATAFLOWS + ("mixed",))
def test_clean_tiled_plan_verifies(dataflow):
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                         memory_budget=TILING)
    assert verify_plan(plan) == []


@pytest.mark.parametrize("dataflow", ("ip_m", "op_m", "gust_m"))
def test_clean_sharded_plan_verifies(dataflow, virtual_mesh):
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                         mesh=virtual_mesh)
    assert not errors_of(verify_plan(plan))


def test_clean_moe_plan_verifies():
    from repro.configs import get_config
    from repro.models.moe import plan_moe

    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    assert verify_plan(plan_moe(cfg, 4)) == []


def test_unknown_plan_type():
    diags = verify_plan(object())
    assert _codes(diags) == ["unknown-plan-type"]


# ---------------------------------------------------------------------------
# mutations: each corrupted invariant is caught with its exact code
# ---------------------------------------------------------------------------


def test_mutation_fingerprint_mismatch():
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS)
    bad = dataclasses.replace(plan, fingerprint="0" * 16)
    assert "fingerprint-mismatch" in _codes(verify_plan(bad))
    with pytest.raises(PlanVerificationError) as exc:
        verify_plan(bad, raise_on_error=True)
    assert exc.value.diagnostics[0].is_error


def test_mutation_wrong_format_layout():
    """A layout carrying the wrong Table 3 format (here: B's BCSC where A's
    BCSR belongs) must be flagged, not silently mis-gathered."""
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS)
    bad = dataclasses.replace(plan, a_layout=plan.b_layout)
    assert "format-mismatch" in _codes(verify_plan(bad))


def test_mutation_wrong_format_subplan():
    """Same corruption one level down, inside a TiledPlan's tile."""
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="gust_m", block_shape=BS,
                         memory_budget=TILING)
    sub = plan.plans[0]
    # gust wants (BCSR, BCSR); splice in an ip-planned BCSC B layout
    donor = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS)
    bad_sub = dataclasses.replace(sub, b_layout=donor.b_layout)
    bad = dataclasses.replace(
        plan, plans=(bad_sub,) + tuple(plan.plans[1:]))
    diags = verify_plan(bad)
    assert "format-mismatch" in _codes(diags)
    assert any(d.location.startswith("plan.plans[0]") for d in diags)


def test_mutation_pad_entry_in_bounds():
    """A padded stream entry that scatters inside the local grid would
    silently accumulate into C — the exact bug class the scan-lane padding
    contract exists to rule out."""
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="gust_m", block_shape=BS)
    sp = plan.index_plan
    pad = lambda arr, v: np.append(np.asarray(arr), np.int32(v))
    bad_sp = dataclasses.replace(
        sp, ci=pad(sp.ci, 0), cj=pad(sp.cj, 0),
        a_slot=pad(sp.a_slot, 0), b_slot=pad(sp.b_slot, 0))
    bad = dataclasses.replace(plan, index_plan=bad_sp)
    diags = verify_plan(bad)
    assert "pad-inbounds" in _codes(diags)
    # the same pad entry pushed OUT of the grid is legal padding
    rows_g = -(-a.shape[0] // BS[0])
    ok_sp = dataclasses.replace(bad_sp, ci=pad(sp.ci, rows_g))
    ok = dataclasses.replace(plan, index_plan=ok_sp)
    assert "pad-inbounds" not in _codes(verify_plan(ok))


def test_mutation_overlapping_tiles():
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS,
                         memory_budget=TILING)
    assert len(plan.tiles) >= 2, "budget must force tiling for this test"
    # duplicate tile 0 over tile 1's slot: cells double-covered AND dropped
    bad = dataclasses.replace(
        plan, tiles=(plan.tiles[0], plan.tiles[0]) + plan.tiles[2:])
    codes = _codes(verify_plan(bad))
    assert "tile-overlap" in codes
    assert "tile-gap" in codes


def test_mutation_scan_plan_on_non_streaming_backend():
    """A plan whose structure needs lax.scan k-slab streaming cannot be
    pointed at a backend that does not declare scan_streaming."""
    from repro.backends import register_backend
    from repro.backends.reference import ReferenceBackend

    class NoScanBackend(ReferenceBackend):
        name = "test-no-scan"
        scan_streaming = False

    register_backend(NoScanBackend(), overwrite=True)
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         memory_budget=TILING, backend="reference")
    assert plan.scan_ok, "op_m under this budget should take the scan path"
    # pallas scans stacked schedules now, so the mutation needs a stub that
    # opts out of scan_streaming to trip the capability check
    bad = dataclasses.replace(plan, backend="test-no-scan")
    assert "backend-capability" in _codes(verify_plan(bad))
    # the supported route is with_backend, which rebuilds the plan shape
    assert not errors_of(verify_plan(plan.with_backend("test-no-scan")))
    assert not errors_of(verify_plan(plan.with_backend("pallas")))


def test_mutation_unknown_backend():
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS)
    bad = dataclasses.replace(plan, backend="no-such-substrate")
    assert "backend-unknown" in _codes(verify_plan(bad))


def test_mutation_moe_plan():
    from repro.models.moe import MoEPlan

    assert "moe-strategy-invalid" in _codes(
        verify_plan(MoEPlan(strategy="auto", tokens=4)))
    assert "moe-tokens-invalid" in _codes(
        verify_plan(MoEPlan(strategy="einsum", tokens=0)))


def test_verify_gate_in_flexagon_plan():
    """The threaded ``verify=`` kwarg raises at build time on corruption.

    Corruption cannot be injected through the public builder, so this
    asserts the two reachable behaviours: clean builds pass the gate, and
    the gate is the same raise path ``verify_plan(raise_on_error=True)``
    takes (exercised above)."""
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="gust_m", block_shape=BS,
                         verify=True)
    assert plan.dataflow == "gust_m"
    cache = PlanCache()
    cache.get(a, b, block_shape=BS, verify=True)
    assert cache.stats["misses"] == 1


def test_verify_cache_key_mismatch():
    a, b = _operands()
    cache = PlanCache()
    plan = cache.get(a, b, block_shape=BS)
    assert verify_cache(cache) == []
    key = next(iter(cache._plans))
    cache._plans[key] = dataclasses.replace(plan, fingerprint="f" * 16)
    codes = _codes(verify_cache(cache))
    assert "cache-key-mismatch" in codes
    assert "fingerprint-mismatch" in codes  # nested verify_plan, relocated


# ---------------------------------------------------------------------------
# jaxpr analysis: purity, cost cross-check, retrace detection
# ---------------------------------------------------------------------------


def test_trace_report_pure_and_deterministic():
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="gust_m", block_shape=BS)
    rep1 = trace_report(plan)
    rep2 = trace_report(plan)
    assert rep1.pure and rep1.callbacks == ()
    assert rep1.flops > 0
    assert rep1.aval_hash == rep2.aval_hash
    assert not any(d.severity == ERROR for d in rep1.diagnostics)


@pytest.mark.parametrize("dataflow", ("ip_m", "op_m"))
def test_trace_report_all_backends_pure(dataflow):
    a, b = _operands()
    for backend in ("reference", "pallas"):
        plan = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                             backend=backend)
        assert trace_report(plan).pure, (dataflow, backend)


@pytest.mark.parametrize("kw", [
    dict(dataflow="gust_m", memory_budget=TILING),       # TiledPlan
    dict(dataflow="mixed", memory_budget=TILING),        # mixed TiledPlan
], ids=["tiled", "mixed"])
def test_trace_report_composed_plans_pure(kw):
    a, b = _operands()
    plan = flexagon_plan(a, b, block_shape=BS, **kw)
    rep = trace_report(plan)
    assert rep.pure and rep.callbacks == ()
    assert rep.aval_hash == trace_report(plan).aval_hash


def test_trace_report_sharded_plan_pure(virtual_mesh):
    a, b = _operands()
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         mesh=virtual_mesh)
    rep = trace_report(plan)
    assert rep.pure and rep.callbacks == ()
    assert rep.aval_hash == trace_report(plan).aval_hash


def test_retrace_detector_stable_across_cache_hits():
    a, b = _operands()
    cache = PlanCache()
    det = RetraceDetector()
    for _ in range(3):
        det.observe(cache.get(a, b, block_shape=BS))
    assert det.stable and det.retraces == []
    assert cache.stats["hits"] == 2


# ---------------------------------------------------------------------------
# regression: ServeEngine decode steps never retrace the cached plan
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serve_decode_steps_share_one_traced_plan():
    """Two decode steps against the same PlanCache entry must present the
    identical traced program — same jaxpr aval hash, zero new plan builds."""
    from repro.configs import get_config
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    from repro.models.ffn import ffn_init
    from repro.models.sparse_linear import compress_ffn
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-360m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fcfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, d_ff=96, vocab=64, ffn_block_sparsity=0.4)
    fparams = ffn_init(jax.random.PRNGKey(0), fcfg)
    fparams["block_mask"] = (jax.random.uniform(
        jax.random.PRNGKey(9), (4, 6)) > 0.4).astype(jnp.float32)
    comp = compress_ffn(fparams, tokens=2, block=16, verify=True)

    eng = ServeEngine(model, params, slots=2, max_seq=64, sparse_ffn=comp,
                      verify=True)
    det = RetraceDetector()
    det.observe(comp.specialize(2).plan_in)      # cache hit, pre-decode
    builds = comp.plan_builds
    prompt = np.random.default_rng(3).integers(0, cfg.vocab, size=5)
    eng.submit(Request(0, prompt, max_new_tokens=3))
    eng.run_to_completion()
    assert eng.stats["decode_steps"] >= 2
    det.observe(comp.specialize(2).plan_in)      # same entry, post-decode
    assert det.stable and det.retraces == []
    # admission planned the one new prompt shape; decode added nothing more
    assert comp.plan_builds == builds + 1
    assert verify_cache(comp.plan_cache) == []


# ---------------------------------------------------------------------------
# AST lint
# ---------------------------------------------------------------------------

_BAD_MODULE = '''
import numpy as np
import jax.numpy as jnp
import jax.experimental.pallas as pl
import dataclasses


def _helper(x):
    return np.asarray(x).sum()


def apply(x):
    if jnp.any(x > 0):
        x = x + 1
    return _helper(x)


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def execute(x):
    return pl.pallas_call(kernel, out_shape=x)(x)


@dataclasses.dataclass
class CustomPlan:
    dataflow: str
'''


def test_lint_catches_all_rule_violations(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(_BAD_MODULE)
    codes = _codes(lint_paths([str(bad)]))
    assert "host-np" in codes
    assert "traced-branch" in codes
    assert "pallas-call" in codes
    assert "plan-pytree" in codes


def test_lint_pragma_suppresses_and_cuts_edge(tmp_path):
    mod = tmp_path / "ok.py"
    mod.write_text(
        "import numpy as np\n\n\n"
        "def _host_fallback(x):\n"
        "    return np.asarray(x)\n\n\n"
        "def apply(x):\n"
        "    return _host_fallback(x)  # lint: host-ok (concrete fast path)\n"
    )
    assert lint_paths([str(mod)]) == []


def _repro_file(tmp_path, name, text):
    """A module that lives under a ``repro/`` path — obs-rule scope."""
    d = tmp_path / "repro" / "subsys"
    d.mkdir(parents=True, exist_ok=True)
    f = d / name
    f.write_text(text)
    return str(f)


def test_lint_obs_time_flags_direct_clock_calls(tmp_path):
    bad = _repro_file(tmp_path, "clocky.py",
                      "import time\n\n\n"
                      "def work():\n"
                      "    t0 = time.time()\n"
                      "    t1 = time.perf_counter()\n"
                      "    return t1 - t0\n")
    diags = lint_paths([bad])
    assert _codes(diags).count("obs-time") == 2
    assert all(d.is_error for d in diags if d.code == "obs-time")


def test_lint_obs_time_pragma_and_scope(tmp_path):
    # a deliberate measurement loop opts out per line
    ok = _repro_file(tmp_path, "measured.py",
                     "import time\n\n\n"
                     "def measure():\n"
                     "    return time.perf_counter()  # lint: time-ok\n")
    assert "obs-time" not in _codes(lint_paths([ok]))
    # the obs layer itself is allowlisted (it IS the clock)
    obs_dir = tmp_path / "repro" / "obs"
    obs_dir.mkdir(parents=True)
    inner = obs_dir / "trace.py"
    inner.write_text("import time\nnow = time.perf_counter_ns()\n")
    assert lint_paths([str(inner)]) == []
    # outside repro/ (benchmarks, tests) the rule never fires
    outside = tmp_path / "bench.py"
    outside.write_text("import time\nt = time.time()\n")
    assert "obs-time" not in _codes(lint_paths([str(outside)]))


def test_lint_obs_stats_flags_string_keyed_accumulation(tmp_path):
    mod = _repro_file(tmp_path, "statsy.py",
                      "def tick(self):\n"
                      "    self.stats['hits'] += 1\n"
                      "    self.stats[0] += 1\n"
                      "    self.stats['ok'] += 1  # lint: stats-ok\n")
    diags = [d for d in lint_paths([mod]) if d.code == "obs-stats"]
    # only the unsuppressed string-keyed line: integer subscripts are list
    # accumulators (core/mrn.py), not metrics drift
    assert len(diags) == 1
    assert ":2" in diags[0].location
    assert not diags[0].is_error        # warning, not a gate failure


def test_lint_clean_on_shipped_tree():
    """The shipped src/ tree must lint clean — same gate as CI."""
    root = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(root / "src")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_diagnostic_shapes():
    d = PlanDiagnostic(code="x", severity=ERROR, message="m", location="l",
                       hint="h")
    assert d.is_error and "x" in str(d) and "l" in str(d)
    with pytest.raises(dataclasses.FrozenInstanceError):
        d.code = "y"
