"""MRN functional model: one substrate, two modes (reduce + merge)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.mrn import merge_fibers, mrn_passes, reduce_clusters


@st.composite
def fiber_set(draw):
    n_fibers = draw(st.integers(1, 12))
    fibers = []
    for _ in range(n_fibers):
        n = draw(st.integers(0, 10))
        coords = draw(st.lists(st.integers(0, 30), min_size=n, max_size=n,
                               unique=True))
        coords = np.sort(np.asarray(coords, np.int64))
        vals = np.arange(1.0, len(coords) + 1.0)
        fibers.append((coords, vals))
    return fibers


@settings(max_examples=50, deadline=None)
@given(fiber_set(), st.sampled_from([2, 4, 64]))
def test_merge_semantics(fibers, leaves):
    """Merged output is coordinate-sorted with duplicates accumulated —
    independent of tree width (width only changes pass count)."""
    (coords, vals), stats = merge_fibers(fibers, leaves=leaves)
    assert np.all(np.diff(coords) > 0)
    # oracle: dict accumulation
    ref = {}
    for c, v in fibers:
        for ci, vi in zip(c, v):
            ref[int(ci)] = ref.get(int(ci), 0.0) + float(vi)
    assert set(map(int, coords)) == set(ref)
    for c_out, v_out in zip(coords, vals):
        assert abs(ref[int(c_out)] - v_out) < 1e-9
    assert stats.elements_in == sum(len(c) for c, _ in fibers)
    assert stats.elements_out == len(ref)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=10),
       st.integers(0, 2 ** 16))
def test_reduce_semantics(sizes, seed):
    rng = np.random.default_rng(seed)
    values = rng.standard_normal(sum(sizes))
    out, stats = reduce_clusters(values, sizes)
    off = 0
    for i, sz in enumerate(sizes):
        assert abs(out[i] - values[off: off + sz].sum()) < 1e-9
        off += sz
    assert stats.elements_out == len(sizes)


def test_multi_pass_merge():
    # more fibers than leaves: paper §3.2.2 requires multiple passes
    fibers = [(np.array([i]), np.array([1.0])) for i in range(100)]
    (_, vals), stats = merge_fibers(fibers, leaves=64)
    assert stats.passes >= 2
    assert mrn_passes(100, 64) >= 2
    assert mrn_passes(64, 64) == 1
    assert mrn_passes(1, 64) == 0
