"""Dataflow selector (phase-1 mapper) + inter-layer transition legality."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DATAFLOWS, LayerShape, estimate, estimate_all,
                        plan_network, select_dataflow,
                        transition_needs_conversion)


def test_estimates_positive():
    ls = LayerShape(512, 512, 512, 0.3, 0.5)
    for df, est in estimate_all(ls).items():
        assert est.flops >= 0 and est.total_bytes > 0
        assert est.time_s > 0


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64),
       st.floats(0.01, 1.0), st.floats(0.01, 1.0))
def test_mn_duality(mb, kb, nb, da, db):
    """N-stationary estimate == M-stationary estimate of the transpose."""
    s = LayerShape(mb * 128, kb * 128, nb * 128, da, db)
    st_ = LayerShape(nb * 128, kb * 128, mb * 128, db, da)
    for base in ("ip", "op", "gust"):
        e_n = estimate(s, base + "_n")
        e_m = estimate(st_, base + "_m")
        assert abs(e_n.time_s - e_m.time_s) < 1e-12


def test_selector_prefers_ip_for_tiny_reused_b():
    # small B that fits cache + stationary-friendly: IP has no psum traffic
    s = LayerShape(256, 256, 256, 0.5, 0.5)
    assert select_dataflow(s) in DATAFLOWS


def test_transition_table4():
    # M-stationary output (CSR) feeds IP(M)/Gust(M)/IP(N) without conversion
    for prod in ("ip_m", "op_m", "gust_m"):
        assert not transition_needs_conversion(prod, "ip_m")
        assert not transition_needs_conversion(prod, "gust_m")
        assert not transition_needs_conversion(prod, "ip_n")
        assert transition_needs_conversion(prod, "op_m")
        assert transition_needs_conversion(prod, "gust_n")
    for prod in ("ip_n", "op_n", "gust_n"):
        assert not transition_needs_conversion(prod, "op_m")
        assert not transition_needs_conversion(prod, "op_n")
        assert not transition_needs_conversion(prod, "gust_n")
        assert transition_needs_conversion(prod, "ip_m")


def test_plan_network_respects_legality():
    layers = [LayerShape(512, 512, 2048, 0.7, 0.4) for _ in range(6)]
    plan = plan_network(layers)
    assert len(plan) == 6
    # planner should avoid paying conversions when a legal chain exists
    convs = sum(transition_needs_conversion(a, b)
                for a, b in zip(plan, plan[1:]))
    assert convs == 0
