"""Test-session config.

Gives the session 8 virtual CPU devices (via the centralized
``repro.config.virtual_devices`` helper) so sharding and distributed-plan
tests exercise real multi-device paths — but NOT the dry-run's 512 (smoke
tests and benches should see a small device count; the dry-run sets its own
flag).  The ``virtual_mesh`` fixture hands tests the corresponding
8-shard mesh.

Also installs a minimal, deterministic ``hypothesis`` fallback when the real
package is absent (the property tests import ``given``/``settings``/
``strategies``): each ``@given`` test then runs against a fixed number of
seeded pseudo-random examples instead of a shrinking search.  With the real
hypothesis installed (see requirements.txt) the shim is inert.
"""
import os
import sys
import types

import pytest

# Every plan the suite builds goes through the analysis verifier
# (repro.analysis.verify_plan raises on any error-severity diagnostic).
# Read at call time by repro.config.verify_default, so setdefault here —
# before any planning — covers the whole session; an explicit REPRO_VERIFY
# in the environment still wins.
os.environ.setdefault("REPRO_VERIFY", "1")

try:
    from repro.config import virtual_devices
    virtual_devices(8)
except ImportError:     # running without PYTHONPATH=src; keep old behaviour
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="session")
def virtual_mesh():
    """An 8-virtual-device CPU mesh — distributed tests run in CI sans TPUs."""
    from repro.launch.mesh import make_virtual_mesh

    return make_virtual_mesh(8)


def _install_hypothesis_stub():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import functools
    import hashlib

    import numpy as np

    class _Strategy:
        """A strategy is just a callable rng -> value."""

        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rng):
            return self._draw_fn(rng)

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    def just(value):
        return _Strategy(lambda rng: value)

    def one_of(*strategies):
        return _Strategy(
            lambda rng: strategies[int(rng.integers(len(strategies)))]
            .example(rng))

    def lists(elements, min_size=0, max_size=10, unique=False):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            if not unique:
                return [elements.example(rng) for _ in range(size)]
            out, seen = [], set()
            for _ in range(1000):
                if len(out) >= size:
                    break
                v = elements.example(rng)
                key = v if not isinstance(v, np.ndarray) else v.tobytes()
                if key not in seen:
                    seen.add(key)
                    out.append(v)
            return out
        return _Strategy(draw)

    def tuples(*strategies):
        return _Strategy(
            lambda rng: tuple(s.example(rng) for s in strategies))

    def composite(fn):
        @functools.wraps(fn)
        def strategy_factory(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda s: s.example(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return strategy_factory

    _DEFAULT_EXAMPLES = 10

    def given(*strategies, **kw_strategies):
        def decorate(test_fn):
            @functools.wraps(test_fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
                # hashlib, not hash(): str hashing is salted per process and
                # would make failures unreproducible across runs
                digest = hashlib.sha1(
                    test_fn.__qualname__.encode()).hexdigest()
                base = int(digest[:8], 16)
                for i in range(n):
                    rng = np.random.default_rng(base + i)
                    drawn = [s.example(rng) for s in strategies]
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    test_fn(*args, *drawn, **kwargs, **drawn_kw)
            # hide the original signature from pytest's fixture resolution
            # (drawn arguments are not fixtures)
            del wrapper.__wrapped__
            wrapper.is_hypothesis_test = True
            return wrapper
        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def decorate(fn):
            # examples are deterministic (no shrinking), so a modest cap
            # keeps the suite fast without losing case diversity
            fn._stub_max_examples = min(max_examples, 15)
            return fn
        return decorate

    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "Deterministic fallback stub (real hypothesis not installed)."
    strategies_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [
        ("integers", integers), ("floats", floats), ("booleans", booleans),
        ("sampled_from", sampled_from), ("just", just), ("one_of", one_of),
        ("lists", lists), ("tuples", tuples), ("composite", composite),
    ]:
        setattr(strategies_mod, name, obj)
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies_mod
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod


_install_hypothesis_stub()
