"""Test-session config.

Gives the session a handful of CPU devices so sharding tests exercise real
multi-device paths — but NOT the dry-run's 512 (smoke tests and benches
should see a small device count; the dry-run sets its own flag).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
