"""repro.obs: span tracing, metrics registry, and the instrumented seams."""
import json
import threading
import tracemalloc

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import trace as trace_mod
from repro.obs.__main__ import validate_chrome
from repro.obs.metrics import Histogram, MetricsRegistry


@pytest.fixture
def tracing():
    """Tracing on, clean tracer; restores env-driven behaviour after."""
    tracer = obs.get_tracer()
    tracer.clear()
    obs.enable()
    yield tracer
    trace_mod._reset_override()
    tracer.clear()


@pytest.fixture
def no_tracing():
    obs.disable()
    yield obs.get_tracer()
    trace_mod._reset_override()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_builds_parent_chain(tracing):
    with obs.span("outer", a=1):
        with obs.span("middle"):
            with obs.span("inner"):
                pass
    spans = {s.name: s for s in tracing.spans()}
    assert set(spans) == {"outer", "middle", "inner"}
    assert spans["outer"].parent is None
    assert spans["middle"].parent == spans["outer"].sid
    assert spans["inner"].parent == spans["middle"].sid
    assert spans["outer"].attrs == {"a": 1}
    # children completed inside the parent's window
    assert spans["outer"].t0_ns <= spans["inner"].t0_ns
    assert spans["inner"].dur_ns <= spans["outer"].dur_ns


def test_span_exception_safety(tracing):
    """A raising body still records the span (error-tagged) and unwinds the
    stack so the next span is not parented under the dead one."""
    with pytest.raises(ValueError):
        with obs.span("failing"):
            raise ValueError("boom")
    with obs.span("after"):
        pass
    spans = {s.name: s for s in tracing.spans()}
    assert spans["failing"].attrs["error"] == "ValueError"
    assert spans["after"].parent is None
    assert tracing.current_span() is None


def test_span_set_attaches_mid_span_attrs(tracing):
    with obs.span("s") as sp:
        sp.set(result=42)
    (rec,) = tracing.spans()
    assert rec.attrs["result"] == 42


def test_traced_decorator(tracing):
    @obs.traced("deco.fn", tag="x")
    def f(v):
        return v + 1

    assert f(1) == 2
    (rec,) = tracing.spans()
    assert rec.name == "deco.fn"
    assert rec.attrs == {"tag": "x"}


def test_disabled_span_is_shared_noop_with_no_retained_allocations(
        no_tracing):
    """With REPRO_TRACE off, span() returns one shared object and retains
    nothing — the hot-path cost is a dict lookup, not an allocation."""
    assert obs.span("a") is obs.span("b", k=1) is trace_mod._NOOP
    before = len(no_tracing)

    def burst():
        for i in range(500):
            with obs.span("hot", i=i):
                pass

    burst()  # warm any lazy interning
    tracemalloc.start()
    s0 = tracemalloc.take_snapshot()
    burst()
    s1 = tracemalloc.take_snapshot()
    tracemalloc.stop()
    retained = sum(d.size_diff for d in s1.compare_to(s0, "filename")
                   if "trace.py" in (d.traceback[0].filename or ""))
    assert retained == 0
    assert len(no_tracing) == before


def test_ring_buffer_bounds_memory():
    tr = trace_mod.Tracer(capacity=8)
    for i in range(20):
        tr.record(f"s{i}", i, 1)
    assert len(tr) == 8
    assert tr.dropped == 12
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]


def test_chrome_export_schema_and_roundtrip(tracing, tmp_path):
    with obs.span("plan.phase1", dataflow="auto"):
        with obs.span("plan.select"):
            pass
    native = tmp_path / "t.jsonl"
    chrome = tmp_path / "t.chrome.json"
    n = tracing.save(str(native))
    tracing.save_chrome(str(chrome))
    assert n == 2

    # native round-trip preserves every field
    back = trace_mod.read_spans(str(native))
    orig = tracing.spans()
    assert [(s.name, s.sid, s.parent, s.t0_ns, s.dur_ns, s.attrs)
            for s in back] == \
        [(s.name, s.sid, s.parent, s.t0_ns, s.dur_ns, s.attrs)
         for s in orig]

    # exported doc passes the CI schema gate and carries the tree
    doc = json.loads(chrome.read_text())
    assert validate_chrome(doc) == []
    events = {e["name"]: e for e in doc["traceEvents"]}
    assert events["plan.select"]["args"]["parent"] == \
        events["plan.phase1"]["args"]["sid"]
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert ev["dur"] >= 0
        assert ev["cat"] == "plan"


def test_validate_chrome_rejects_bad_docs():
    assert validate_chrome([]) != []
    assert validate_chrome({"traceEvents": [{"ph": "X"}]}) != []
    missing_parent = {"traceEvents": [
        {"ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 1, "name": "a",
         "args": {"sid": 5, "parent": 9}}]}
    assert any("unbalanced" in e for e in validate_chrome(missing_parent))


def test_summarize_table(tracing):
    for i in range(4):
        tr = obs.get_tracer()
        tr.record("plan.x", 0, (i + 1) * 1000)
    table = obs.summarize(tracing.spans())
    assert "plan.x" in table
    assert "count" in table and "p99_us" in table


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_counter_gauge_snapshot():
    reg = MetricsRegistry()
    reg.counter("cache.hits").inc()
    reg.counter("cache.hits").inc(2)
    reg.gauge("dist.ici_bytes").set(128.0)
    snap = reg.snapshot()
    assert snap["cache.hits"] == {"type": "counter", "value": 3.0}
    assert snap["dist.ici_bytes"]["value"] == 128.0
    assert json.loads(reg.to_json())["cache.hits"]["value"] == 3.0
    # prefix filtering
    assert list(reg.snapshot(prefix="cache.")) == ["cache.hits"]


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_percentiles_match_numpy_within_bucket_ratio():
    """Bucketed quantiles land within one log-bucket ratio of numpy's
    exact percentiles (the documented resolution contract)."""
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-7.0, sigma=1.5, size=5000)  # latency-like
    h = Histogram("serve.latency.s")
    for v in vals:
        h.observe(float(v))
    ratio = h.buckets[1] / h.buckets[0]
    for q in (0.50, 0.90, 0.99):
        exact = float(np.percentile(vals, q * 100))
        est = h.quantile(q)
        assert exact / ratio <= est <= exact * ratio, (q, exact, est)
    assert h.count == len(vals)
    assert h.sum == pytest.approx(float(vals.sum()))
    snap = h.snapshot()
    assert snap["min"] == pytest.approx(float(vals.min()))
    assert snap["max"] == pytest.approx(float(vals.max()))
    assert snap["p50"] == h.quantile(0.50)


def test_metrics_thread_safety_smoke():
    reg = MetricsRegistry()

    def work():
        for _ in range(1000):
            reg.counter("c").inc()
            reg.histogram("h").observe(0.001)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("c").value == 8000
    assert reg.histogram("h").count == 8000


def test_tracer_thread_spans_do_not_cross_parent(tracing):
    """Span stacks are per-thread: concurrent spans never parent across
    threads."""
    def worker(tag):
        for _ in range(50):
            with obs.span(f"t.{tag}"):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(s.parent is None for s in tracing.spans())
    assert len(tracing) == 200


# ---------------------------------------------------------------------------
# instrumented seams
# ---------------------------------------------------------------------------


def test_flexagon_plan_emits_phase1_spans_and_metrics(tracing):
    from repro import flexagon_plan
    from repro.core import random_sparse_dense

    reg = obs.get_registry()
    builds0 = reg.value("plan.builds")
    rng = np.random.default_rng(0)
    a = random_sparse_dense(rng, (32, 32), density=0.3, block_shape=(8, 8))
    b = random_sparse_dense(rng, (32, 48), density=0.6, block_shape=(8, 8))
    plan = flexagon_plan(a, b, block_shape=(8, 8, 8))
    spans = {s.name: s for s in tracing.spans()}
    assert {"plan.phase1", "plan.select", "plan.tables",
            "plan.prepare"} <= set(spans)
    assert spans["plan.select"].parent == spans["plan.phase1"].sid
    assert spans["plan.phase1"].attrs["chosen"] == plan.dataflow
    assert reg.value("plan.builds") == builds0 + 1
    assert reg.get("policy.select_s").count >= 1


def test_tiled_apply_span_carries_tier_traffic(tracing):
    from repro import MemoryBudget, TiledPlan, flexagon_plan
    from repro.core import random_sparse_dense

    rng = np.random.default_rng(0)
    a = random_sparse_dense(rng, (64, 64), density=0.4, block_shape=(16, 16))
    b = random_sparse_dense(rng, (64, 64), density=0.6, block_shape=(16, 16))
    plan = flexagon_plan(a, b, block_shape=(16, 16, 16),
                         memory_budget=MemoryBudget(l1_bytes=4 << 10,
                                                    l2_bytes=8 << 10))
    assert isinstance(plan, TiledPlan)
    np.asarray(plan.apply(a, b))
    applies = [s for s in tracing.spans() if s.name == "memory.tiled.apply"]
    assert len(applies) == 1
    attrs = applies[0].attrs
    assert attrs["tiles"] == plan.n_tiles
    assert attrs["dram_bytes"] > 0 and attrs["l1_bytes"] > 0


def test_plan_cache_counts_into_global_registry():
    from repro.api import PlanCache
    from repro.core import random_sparse_dense

    reg = obs.get_registry()
    h0, m0 = reg.value("cache.hits"), reg.value("cache.misses")
    rng = np.random.default_rng(0)
    a = random_sparse_dense(rng, (32, 32), density=0.3, block_shape=(8, 8))
    b = random_sparse_dense(rng, (32, 32), density=0.6, block_shape=(8, 8))
    cache = PlanCache()
    cache.get(a, b, block_shape=(8, 8, 8))
    cache.get(a, b, block_shape=(8, 8, 8))
    assert reg.value("cache.misses") == m0 + 1
    assert reg.value("cache.hits") == h0 + 1


# ---------------------------------------------------------------------------
# ServeEngine end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_engine():
    """One engine run with tracing on: 3 requests through 2 slots."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    tracer = obs.get_tracer()
    tracer.clear()
    obs.enable()
    try:
        cfg = get_config("smollm-360m", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=2, max_seq=64)
        rng = np.random.default_rng(0)
        for rid in range(3):
            prompt = rng.integers(0, cfg.vocab, size=5)
            eng.submit(Request(rid, prompt, max_new_tokens=4))
        results = eng.run_to_completion()
        spans = tracer.spans()
    finally:
        trace_mod._reset_override()
        tracer.clear()
    return eng, results, spans


def test_serve_latency_histograms_populated(served_engine):
    eng, results, _ = served_engine
    assert len(results) == 3
    lat = eng.latency_stats()
    for name in ("serve.latency.queue_s", "serve.latency.prefill_s",
                 "serve.latency.decode_step_s", "serve.latency.request_s"):
        assert name in lat, name
        assert lat[name]["count"] >= 1
        assert lat[name]["p50"] > 0
        assert lat[name]["p99"] >= lat[name]["p50"]
    assert lat["serve.latency.request_s"]["count"] == 3
    assert eng.stats["completed"] == 3
    assert eng.stats["decode_steps"] == \
        lat["serve.latency.decode_step_s"]["count"]


def test_serve_request_span_trees(served_engine):
    _, _, spans = served_engine
    requests = [s for s in spans if s.name == "serve.request"]
    prefills = [s for s in spans if s.name == "serve.prefill"]
    decodes = [s for s in spans if s.name == "serve.decode_step"]
    assert len(requests) == 3 and len(prefills) == 3
    assert decodes, "fused decode steps must be traced"
    # every request roots its own tree: exactly one prefill child each
    by_parent = {}
    for p in prefills:
        by_parent.setdefault(p.parent, []).append(p)
    for req in requests:
        assert req.parent is None
        children = by_parent.get(req.sid, [])
        assert len(children) == 1
        assert children[0].attrs["rid"] == req.attrs["rid"]
        assert req.attrs["new_tokens"] == 4


def test_serve_stats_property_returns_independent_snapshots(served_engine):
    """Satellite regression: mutating live policy/cache stats after a
    snapshot must not rewrite previously returned snapshots."""
    eng, _, _ = served_engine
    s1 = eng.stats
    s2 = eng.stats
    assert s1 is not s2 and s1 == s2
    s1["completed"] = 10 ** 9
    assert eng.stats["completed"] == s2["completed"] != s1["completed"]


def test_sync_plan_stats_deep_copies_nested_dicts():
    """The original aliasing bug: _sync_plan_stats copied policy stats
    shallowly, so later nested-dict mutation leaked into old snapshots."""
    import copy

    class _Policy:
        def __init__(self):
            self.stats = {"nested": {"measurements": 0}}

    class _FFN:
        plan_builds = 1
        plan_hits = 2
        backend = "reference"
        cache_stats = {"hits": 0, "inner": {"deep": 0}}

        def __init__(self):
            self.policy = _Policy()

    from repro.serve.engine import ServeEngine

    eng = ServeEngine.__new__(ServeEngine)   # stats plumbing only
    eng.metrics = obs.MetricsRegistry()
    eng._plan_stats = {"plan_builds": 0, "plan_hits": 0}
    eng.sparse_ffn = _FFN()
    eng.decode_ffn = None
    eng._sync_plan_stats()
    snap = eng.stats
    before = copy.deepcopy(snap)
    # mutate the live nested dicts the old code aliased
    eng.sparse_ffn.policy.stats["nested"]["measurements"] = 999
    eng.sparse_ffn.cache_stats["inner"]["deep"] = 999
    assert snap == before, "snapshot must not alias live policy/cache dicts"
