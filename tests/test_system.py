"""End-to-end behaviour tests: every assigned architecture instantiates its
reduced-config family, runs one forward/train step on CPU, and produces
finite outputs of the right shape (deliverable f smoke tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.train import init_train_state, make_train_step

#: jamba's hybrid smoke config dominates this module's wall clock; its
#: parametrizations are ``slow``-marked so the CI smoke lane skips them
_mark_heavy = lambda arch: pytest.param(arch, marks=pytest.mark.slow) \
    if arch in ("jamba-v0.1-52b", "seamless-m4t-large-v2") else arch
_ARCHS = [_mark_heavy(a) for a in ARCH_IDS]


def _batch(cfg, b=2, s=16, seed=1):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, 1)}
    if cfg.frontend == "frames":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, s // 2, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", _ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    if cfg.kind != "encdec":
        logits = model.logits(params, batch["tokens"])
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", [_mark_heavy(a) for a in
                                  ("smollm-360m", "granite-moe-1b-a400m",
                                   "rwkv6-3b", "jamba-v0.1-52b",
                                   "seamless-m4t-large-v2")])
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(global_batch=4, seq_len=16, lr=1e-3, warmup_steps=2,
                       total_steps=10)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    batch = _batch(cfg, b=4, s=16)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_training_reduces_loss():
    from repro.data.pipeline import make_batch_iterator
    cfg = get_config("smollm-360m", smoke=True)
    tcfg = TrainConfig(global_batch=8, seq_len=32, lr=5e-3, warmup_steps=5,
                       total_steps=40, microbatches=2)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    it = make_batch_iterator(cfg, tcfg)
    losses = []
    for _, b in zip(range(40), it):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    it.close()
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_grad_compression_trains():
    from repro.data.pipeline import make_batch_iterator
    cfg = get_config("smollm-360m", smoke=True)
    tcfg = TrainConfig(global_batch=8, seq_len=32, lr=5e-3, warmup_steps=5,
                       total_steps=30, grad_compression=True)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    assert state.ef is not None
    step = jax.jit(make_train_step(model, tcfg))
    it = make_batch_iterator(cfg, tcfg)
    losses = []
    for _, b in zip(range(30), it):
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    it.close()
    # int8 + error feedback must still converge
    assert losses[-1] < losses[0] - 0.3
    # error-feedback residuals are live
    assert any(float(jnp.abs(e).max()) > 0 for e in jax.tree.leaves(state.ef))
