"""Checkpoint: roundtrip, async, retention, elastic re-shard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(7, t, blocking=True)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    restored, step = ck.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        ck.save(s, tree())
    ck.wait()
    assert ck.all_steps() == [30, 40]
    assert ck.latest_step() == 40


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree(), blocking=True)
    bad = {"a": jax.ShapeDtypeStruct((4, 4), jnp.float32),
           "b": {"c": jax.ShapeDtypeStruct((5,), jnp.int32)}}
    with pytest.raises(ValueError):
        ck.restore(bad)


def test_elastic_reshard(tmp_path):
    """Save under one mesh, restore under a different mesh shape."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (set XLA_FLAGS host device count)")
    ck = Checkpointer(str(tmp_path))
    mesh_a = jax.make_mesh((2, 1), ("data", "model"))
    x = jnp.arange(16.0).reshape(4, 4)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", None)))
    ck.save(1, {"x": xa}, blocking=True)

    mesh_b = jax.make_mesh((1, 2), ("data", "model"))
    like = {"x": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    shardings = {"x": NamedSharding(mesh_b, P(None, "model"))}
    restored, _ = ck.restore(like, shardings=shardings)
    assert np.array_equal(np.asarray(restored["x"]), np.asarray(x))
    assert restored["x"].sharding.spec == P(None, "model")
