"""Decode-path consistency: step-by-step decode and prefill+decode must
reproduce the teacher-forced forward logits for every architecture family.

MoE archs run with all experts selected (removes the discrete routing
boundary that bf16 noise can flip — a property of MoE, not a bug)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

TOL = 3e-2
#: jamba is by far the heaviest smoke config (hybrid attn+mamba+moe stack);
#: its parametrizations carry the ``slow`` marker so the CI smoke lane
#: (``-m "not slow"``) skips them while the full lane keeps coverage
_JAMBA = pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow)
ARCHS = ["smollm-360m", "qwen2-1.5b", "granite-34b", "llama3.2-3b",
         "chameleon-34b", "rwkv6-3b", _JAMBA, "mixtral-8x7b",
         "granite-moe-1b-a400m"]


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, top_k=cfg.moe.num_experts,
                                         strategy="scatter"))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 10
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model.logits(params, tok, remat=False)
                      .astype(jnp.float32))
    scale = np.abs(full).max() + 1e-6

    cache = model.init_cache(B, max_seq=24)
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache, tok[:, t:t + 1])
        outs.append(np.asarray(logits.astype(jnp.float32)))
    dec = np.concatenate(outs, axis=1)
    assert np.abs(dec - full).max() / scale < TOL


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-3b",
                                  _JAMBA, "mixtral-8x7b"])
def test_prefill_then_decode(arch):
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, S0 = 2, 10, 6
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model.logits(params, tok, remat=False)
                      .astype(jnp.float32))
    scale = np.abs(full).max() + 1e-6

    cache = model.init_cache(B, max_seq=24)
    logits_p, cache = model.prefill(params, tok[:, :S0], cache)
    assert np.abs(np.asarray(logits_p.astype(jnp.float32))[:, 0]
                  - full[:, S0 - 1]).max() / scale < TOL
    outs = []
    for t in range(S0, S):
        logits, cache = model.decode_step(params, cache, tok[:, t:t + 1])
        outs.append(np.asarray(logits.astype(jnp.float32)))
    dec = np.concatenate(outs, axis=1)
    assert np.abs(dec - full[:, S0:]).max() / scale < TOL


def test_encdec_decode_matches_forward():
    from repro.models.layers import dense, embedding_lookup, rmsnorm
    cfg = get_config("seamless-m4t-large-v2", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model),
                               jnp.bfloat16)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 6), 0, cfg.vocab)
    mem = model.encode(params, frames, remat=False)
    x = embedding_lookup(params["embed"], tok)
    x = model._decoder_pass(params, x, jnp.arange(6), mem, remat=False)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    full = np.asarray(dense(params["lm_head"], x).astype(jnp.float32))
    scale = np.abs(full).max() + 1e-6

    cache = model.init_cache(B, max_seq=24)
    logits, cache = model.prefill(
        params, {"frames": frames, "tokens": tok[:, :1]}, cache)
    outs = [np.asarray(logits.astype(jnp.float32))]
    for t in range(1, 6):
        logits, cache = model.decode_step(params, cache, tok[:, t:t + 1])
        outs.append(np.asarray(logits.astype(jnp.float32)))
    dec = np.concatenate(outs, axis=1)
    assert np.abs(dec - full).max() / scale < TOL


@pytest.mark.slow
def test_swa_ring_buffer_long_context():
    """SWA decode with a ring cache smaller than the context must match a
    full-cache reference restricted to the window."""
    from repro.configs.base import LayerPattern, ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=1, d_head=16, d_ff=64, vocab=64,
                      pattern=LayerPattern(mixers=("swa",)), swa_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 20
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = np.asarray(model.logits(params, tok, remat=False)
                      .astype(jnp.float32))
    cache = model.init_cache(B, max_seq=8)       # ring = window
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache, tok[:, t:t + 1])
        outs.append(np.asarray(logits.astype(jnp.float32)))
    dec = np.concatenate(outs, axis=1)
    err = np.abs(dec - full).max() / (np.abs(full).max() + 1e-6)
    assert err < TOL, err
