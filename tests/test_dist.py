"""Distributed plan execution (repro.dist, DESIGN.md §13).

Sharded-vs-single-device parity across all six dataflows × both block input
formats × {1, 2, 8}-shard meshes, the shard_map/serial paths, the
interconnect traffic tier, mesh-aware plan caching (property test), and the
mesh construction helpers.  Runs on 8 virtual CPU devices provisioned by
conftest via ``repro.config.virtual_devices``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import (DistPartition, FlexagonPlan, MemoryBudget, PlanCache,
                   ShardedPlan, SparseOperand, TiledPlan, flexagon_plan,
                   get_backend)
from repro.core import random_sparse_dense
from repro.core.dataflows import DATAFLOWS
from repro.dist import Partitioner, default_axis, mesh_key
from repro.launch.mesh import make_local_mesh, make_virtual_mesh
from repro.memory import sharded_traffic
from repro.memory.tiling import Tile

BS = (8, 8, 8)


def _case(seed=0, m=32, k=48, n=40, da=0.4, db=0.5):
    rng = np.random.default_rng(seed)
    a = random_sparse_dense(rng, (m, k), density=da, block_shape=BS[:2])
    b = random_sparse_dense(rng, (k, n), density=db, block_shape=BS[1:])
    return a, b


@pytest.fixture(scope="module")
def ab():
    return _case()


# ---------------------------------------------------------------------------
# sharded-vs-single-device parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 2, 8])
@pytest.mark.parametrize("fmt", ["bcsr", "bcsc"])
@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_sharded_parity(dataflow, fmt, shards, ab, virtual_mesh):
    a, b = ab
    mesh = make_virtual_mesh(shards)
    a_op = SparseOperand.from_dense(a, format=fmt, block_shape=BS[:2])
    b_op = SparseOperand.from_dense(b, format=fmt, block_shape=BS[1:])
    plan = flexagon_plan(a_op, b_op, dataflow=dataflow, block_shape=BS,
                         mesh=mesh)
    single = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS)
    ref = np.asarray(single.apply(a, b))
    out = np.asarray(plan.apply(a_op, b_op))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
    if shards > 1:
        assert isinstance(plan, ShardedPlan)
        assert plan.n_shards == shards
        assert plan.axis == default_axis(dataflow)
        assert plan.shard_ok         # reference backend runs the shard_map
    else:
        assert isinstance(plan, FlexagonPlan)   # 1 shard degrades gracefully


def test_sharded_parity_vs_tiled_single_device(ab, virtual_mesh):
    """Acceptance: sharded apply == single-device TiledPlan result."""
    a, b = ab
    budget = MemoryBudget(l1_bytes=1 << 10, l2_bytes=2 << 10)
    tiled_some = False
    for dataflow in ("ip_m", "op_m", "gust_m"):
        tiled = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                              memory_budget=budget)
        tiled_some |= isinstance(tiled, TiledPlan)
        sharded = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                                mesh=virtual_mesh, memory_budget=budget)
        assert isinstance(sharded, ShardedPlan)
        np.testing.assert_allclose(np.asarray(sharded.apply(a, b)),
                                   np.asarray(tiled.apply(a, b)),
                                   rtol=1e-5, atol=1e-5)
    assert tiled_some    # the budget is small enough to tile at least one


def test_jit_apply_and_pytree_roundtrip(ab, virtual_mesh):
    a, b = ab
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         mesh=virtual_mesh)
    out = np.asarray(jax.jit(plan.apply)(a, b))
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_allclose(np.asarray(plan2.apply(a, b)), out,
                               rtol=1e-6, atol=1e-6)


def test_serial_fallback_backend(ab):
    """A backend without collective_merge gets the unrolled shard loop.

    Every built-in backend now declares collective_merge, so the fallback
    is exercised through a locally registered stub that opts out.
    """
    from repro.backends.reference import ReferenceBackend

    class NoCollectiveBackend(ReferenceBackend):
        name = "test-no-collective"
        collective_merge = False

    a, b = ab
    mesh = make_virtual_mesh(2)
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS, mesh=mesh,
                         backend=NoCollectiveBackend())
    assert isinstance(plan, ShardedPlan)
    assert not plan.shard_ok
    np.testing.assert_allclose(np.asarray(plan.apply(a, b)), a @ b,
                               rtol=1e-4, atol=1e-4)


def test_pallas_collective_merge(ab):
    """pallas shards through shard_map + psum (collective merge parity)."""
    a, b = ab
    mesh = make_virtual_mesh(2)
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS, mesh=mesh,
                         backend="pallas", interpret=True)
    assert isinstance(plan, ShardedPlan)
    assert plan.shard_ok
    np.testing.assert_allclose(np.asarray(plan.apply(a, b)), a @ b,
                               rtol=1e-4, atol=1e-4)


def test_partition_override_and_budget_within_shard(ab, virtual_mesh):
    a, b = ab
    plan = flexagon_plan(a, b, dataflow="ip_m", block_shape=BS,
                         mesh=virtual_mesh,
                         partition=DistPartition(axis="m", shards=2))
    assert plan.axis == "m" and plan.n_shards == 2
    np.testing.assert_allclose(np.asarray(plan.apply(a, b)), a @ b,
                               rtol=1e-4, atol=1e-4)
    # a budget small enough to tile within each shard: placement stays
    # orthogonal to tiling — some shards become TiledPlans (serial path)
    budget = MemoryBudget(l1_bytes=1 << 10, l2_bytes=2 << 10)
    plan_t = flexagon_plan(a, b, dataflow="gust_m", block_shape=BS,
                           mesh=make_virtual_mesh(2), memory_budget=budget)
    assert isinstance(plan_t, ShardedPlan)
    assert any(isinstance(p, TiledPlan) for p in plan_t.plans)
    np.testing.assert_allclose(np.asarray(plan_t.apply(a, b)), a @ b,
                               rtol=1e-4, atol=1e-4)


def test_with_backend_retarget(ab, virtual_mesh):
    a, b = ab
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         mesh=virtual_mesh)
    sim = plan.with_backend("simulator")
    assert isinstance(sim, ShardedPlan) and sim.backend == "simulator"
    np.testing.assert_allclose(np.asarray(sim.apply(a, b)),
                               np.asarray(plan.apply(a, b)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# interconnect traffic tier
# ---------------------------------------------------------------------------


def test_report_has_interconnect_tier(ab, virtual_mesh):
    a, b = ab
    sim = get_backend("simulator")
    op = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                       mesh=virtual_mesh, backend=sim)
    rep = sim.report(op)
    assert rep.shards == 8 and len(rep.per_shard) == 8
    assert rep.traffic.ici_bytes > 0            # k-slab psum merge
    assert rep.traffic.l1_bytes > 0 and rep.traffic.dram_bytes > 0
    assert rep.traffic.total_bytes >= rep.traffic.ici_bytes
    # disjoint-output partitions exchange nothing
    for dataflow in ("ip_m", "gust_m"):
        p = flexagon_plan(a, b, dataflow=dataflow, block_shape=BS,
                          mesh=virtual_mesh, backend=sim)
        assert sim.report(p).traffic.ici_bytes == 0
    assert op.dist_stats["collective"] == "psum"
    assert op.dist_stats["ici_bytes"] == rep.traffic.ici_bytes


def test_report_with_budget_and_padding_shards(ab, virtual_mesh):
    """Regression: report() on a budgeted sharded plan whose shard count
    does not divide the block grid (padding-only shards) must not crash —
    the shard slices are re-derived zero-padded, not zero-size."""
    a, b = ab                    # K grid = 6 blocks, 8 k-slab shards
    sim = get_backend("simulator")
    budget = MemoryBudget(l1_bytes=1 << 10, l2_bytes=2 << 10)
    plan = flexagon_plan(a, b, dataflow="op_m", block_shape=BS,
                         mesh=virtual_mesh, memory_budget=budget,
                         backend=sim)
    rep = sim.report(plan)
    assert rep.shards == 8 and rep.traffic.ici_bytes > 0


def test_sharded_traffic_scaling(ab):
    """More k-slab shards → more interconnect merge traffic."""
    a, b = ab
    from repro.core.formats import block_occupancy

    occ_a = block_occupancy(a, BS[:2])
    occ_b = block_occupancy(b, BS[1:])
    t2 = sharded_traffic("op_m", occ_a, occ_b, BS, 2)
    t8 = sharded_traffic("op_m", occ_a, occ_b, BS, 8)
    assert 0 < t2.ici_bytes < t8.ici_bytes
    t_ip = sharded_traffic("ip_m", occ_a, occ_b, BS, 8)
    assert t_ip.ici_bytes == 0
    assert sharded_traffic("op_m", occ_a, occ_b, BS, 1).ici_bytes == 0


def test_policies_rank_with_mesh(ab, virtual_mesh):
    a, b = ab
    for policy in ("heuristic", "simulator"):
        plan = flexagon_plan(a, b, block_shape=BS, mesh=virtual_mesh,
                             policy=policy)
        assert isinstance(plan, ShardedPlan)
        assert plan.dataflow in DATAFLOWS


# ---------------------------------------------------------------------------
# plan cache: mesh identity
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(st.sampled_from([1, 2, 4, 8]), st.sampled_from([1, 2, 4, 8]))
def test_plan_cache_never_crosses_meshes(s1, s2):
    """Property: a plan built for one mesh is never served for another."""
    a, b = _case(seed=3, m=16, k=24, n=16)
    cache = PlanCache()
    m1, m2 = make_virtual_mesh(s1), make_virtual_mesh(s2)
    p1 = cache.get(a, b, dataflow="op_m", block_shape=BS, mesh=m1)
    hits_before = cache.hits
    p2 = cache.get(a, b, dataflow="op_m", block_shape=BS, mesh=m2)
    shards1 = p1.n_shards if isinstance(p1, ShardedPlan) else 1
    shards2 = p2.n_shards if isinstance(p2, ShardedPlan) else 1
    assert shards1 == s1 and shards2 == s2
    if mesh_key(m1) == mesh_key(m2):
        assert cache.hits == hits_before + 1 and p2 is p1
    else:
        assert cache.hits == hits_before and p2 is not p1
    # same mesh again → always a hit
    p3 = cache.get(a, b, dataflow="op_m", block_shape=BS, mesh=m2)
    assert p3 is p2


# ---------------------------------------------------------------------------
# partitioner + mesh helpers
# ---------------------------------------------------------------------------


def test_partitioner_strategies():
    assert default_axis("ip_m") == "n" and default_axis("ip_n") == "m"
    assert default_axis("op_m") == "k" and default_axis("op_n") == "k"
    assert default_axis("gust_m") == "m" and default_axis("gust_n") == "n"
    part = Partitioner("op_m")
    tiles = part.shard_tiles((4, 6, 5), 4)
    assert len(tiles) == 4
    assert all(t.k1 - t.k0 == 2 for t in tiles)       # uniform padded slabs
    assert tiles[-1].k1 == 8                          # padded past the grid
    # tile-stream placement follows the strategy axis
    stream = [Tile(0, 4, k, k + 2, 0, 5) for k in range(0, 8, 2)]
    assert part.assign(stream, 2) == [0, 0, 1, 1]


def test_mesh_helpers(virtual_mesh):
    local = make_local_mesh()
    assert local.devices.shape[1] == 1                # (n, 1), n >= 1
    assert tuple(virtual_mesh.axis_names) == ("shards",)
    assert np.asarray(virtual_mesh.devices).size == 8
    one = make_virtual_mesh(1)
    assert np.asarray(one.devices).size == 1
    with pytest.raises(RuntimeError):
        make_virtual_mesh(10_000)


def test_serve_engine_reports_dist_stats(virtual_mesh):
    """A sharded CompressedFFN attached to the engine surfaces mesh /
    shard / collective telemetry through ``stats["dist"]``."""
    from repro.configs import get_config
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    from repro.models.ffn import ffn_init
    from repro.models.sparse_linear import compress_ffn
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("smollm-360m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fcfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, d_ff=96, vocab=64, ffn_block_sparsity=0.4)
    fparams = ffn_init(jax.random.PRNGKey(0), fcfg)
    fparams["block_mask"] = (jax.random.uniform(
        jax.random.PRNGKey(9), (4, 6)) > 0.4).astype(jnp.float32)
    comp = compress_ffn(fparams, tokens=2, block=16, mesh=virtual_mesh,
                        partition=DistPartition(shards=2))
    eng = ServeEngine(model, params, slots=2, max_seq=64, sparse_ffn=comp)
    assert isinstance(eng.decode_ffn.plan_in, ShardedPlan)
    dist = eng.stats["dist"]
    assert dist["shards"] == 2 and dist["mesh_shape"] == (8,)
    assert dist["ici_bytes"] >= 0
    rng = np.random.default_rng(3)
    eng.submit(Request(0, rng.integers(0, cfg.vocab, size=5),
                       max_new_tokens=3))
    eng.run_to_completion()
    assert eng.stats["completed"] == 1
    assert eng.stats["dist"]["shards"] == 2    # survives stat syncs


def test_compressed_ffn_sharded_decode(virtual_mesh):
    """CompressedFFN(mesh=...) plans sharded matmuls and caches per mesh."""
    from repro.models.sparse_linear import CompressedFFN, sparse_ffn_apply

    rng = np.random.default_rng(0)
    d, f = 16, 32
    mask = rng.random((d // 8, f // 8)) < 0.6
    wg = (rng.standard_normal((d, f)) *
          np.kron(mask, np.ones((8, 8)))).astype(np.float32)
    wu = (rng.standard_normal((d, f)) *
          np.kron(mask, np.ones((8, 8)))).astype(np.float32)
    wd = (rng.standard_normal((f, d)) *
          np.kron(mask.T, np.ones((8, 8)))).astype(np.float32)
    comp = CompressedFFN(wg, wu, wd, tokens=8, block=8, mesh=virtual_mesh,
                         partition=DistPartition(shards=2))
    entry = comp.specialize(8)
    assert isinstance(entry.plan_in, ShardedPlan)
    assert entry.plan_in.n_shards == 2
    x = rng.standard_normal((1, 8, d)).astype(np.float32)
    y = np.asarray(sparse_ffn_apply(comp, jnp.asarray(x)))
    x2 = x.reshape(8, d)
    ref = (jax.nn.silu(x2 @ wg) * (x2 @ wu)) @ wd
    np.testing.assert_allclose(y.reshape(8, d), ref, rtol=1e-3, atol=1e-3)
