"""Property: all six dataflows compute the same C as the dense oracle
(paper §2.2 — dataflows change *how*, never *what*)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DATAFLOWS, OUTPUT_MAJOR, run_dataflow
from repro.core.dataflows import build_gust_plan, build_ip_plan, build_op_plan
from repro.core.formats import dense_to_bcsc, dense_to_bcsr, \
    random_sparse_dense


@st.composite
def spmspm_case(draw):
    m = draw(st.integers(1, 5)) * 8
    k = draw(st.integers(1, 5)) * 8
    n = draw(st.integers(1, 5)) * 8
    da = draw(st.floats(0.0, 1.0))
    db = draw(st.floats(0.05, 1.0))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    a = random_sparse_dense(rng, (m, k), density=da)
    b = random_sparse_dense(rng, (k, n), density=db)
    return a, b


@settings(max_examples=25, deadline=None)
@given(spmspm_case(), st.sampled_from(DATAFLOWS))
def test_dataflow_matches_oracle(case, dataflow):
    a, b = case
    ref = a.astype(np.float64) @ b.astype(np.float64)
    out = np.asarray(run_dataflow(dataflow, a, b, (8, 8)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dataflow", DATAFLOWS)
def test_run_dataflow_nonsquare_blocks(dataflow):
    """Regression: a (bm, bk, bn) block shape with bm != bk != bn must give
    B blocks of (bk, bn) — the seed derived them as (bk, bk)."""
    rng = np.random.default_rng(21)
    a = random_sparse_dense(rng, (12, 20), density=0.5)
    b = random_sparse_dense(rng, (20, 18), density=0.6)
    ref = a.astype(np.float64) @ b.astype(np.float64)
    out = np.asarray(run_dataflow(dataflow, a, b, (4, 5, 6)))
    assert out.shape == ref.shape
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    # legacy 2-tuple still accepted (bn defaults to bk)
    out2 = np.asarray(run_dataflow(dataflow, a, b, (4, 5)))
    np.testing.assert_allclose(out2, ref, rtol=1e-4, atol=1e-4)


def test_output_major_table():
    # Table 3: M-stationary emits row-major, N-stationary column-major
    assert OUTPUT_MAJOR["ip_m"] == OUTPUT_MAJOR["op_m"] == \
        OUTPUT_MAJOR["gust_m"] == "csr"
    assert OUTPUT_MAJOR["ip_n"] == OUTPUT_MAJOR["op_n"] == \
        OUTPUT_MAJOR["gust_n"] == "csc"


@settings(max_examples=15, deadline=None)
@given(spmspm_case())
def test_plan_invariants(case):
    """Work-list sizes equal the effectual block-pair count in every plan."""
    a, b = case
    bs = (8, 8)
    a_csr = dense_to_bcsr(a, bs)
    a_csc = dense_to_bcsc(a, bs)
    b_csr = dense_to_bcsr(b, bs)
    b_csc = dense_to_bcsc(b, bs)
    bit_a = a_csr.bitmap()
    bit_b = b_csr.bitmap()
    # effectual block pairs = sum_k (rows in A col k) × (cols in B row k)
    expected = int((bit_a.sum(0) * bit_b.sum(1)).sum())

    op = build_op_plan(a_csc, b_csr)
    gust = build_gust_plan(a_csr, b_csr)
    ip = build_ip_plan(a_csr, b_csc)
    assert op.a_slot.size == expected
    assert gust.a_slot.size == expected
    assert int(ip.npairs.sum()) == expected
    # OP is k-ordered, Gust is output-row-ordered
    assert op.order == "k" and gust.order == "i"
    if gust.ci.size:
        assert np.all(np.diff(gust.ci) >= 0)
